"""Hierarchy subsystem: golden forests, oracle parity across engines,
query answers, serialization, and the batched query service."""
import io

import jax
import numpy as np
import pytest

from repro.core import ref
from repro.core.graph import BipartiteGraph, powerlaw_bipartite
from repro.core.peel import PeelStats, tip_decomposition, wing_decomposition
from repro.hierarchy import (
    HierarchyService,
    HQuery,
    build_hierarchy,
    density_profile,
    lca_entities,
    lca_nodes,
    load_hierarchy,
    max_k_containing,
    node_of,
    pack_forest,
    save_hierarchy,
    subgraph_at,
    top_densest_leaves,
)
from repro.hierarchy.build import _label_components


# ------------------------------------------------------------------ helpers
def _two_blobs():
    """Two K22 butterfly blobs + one butterfly-free bridge edge.

    Edge ids (lexicographic): 0..3 = K22 on U{0,1}×V{0,1},
    4 = bridge (1,2), 5..8 = K22 on U{2,3}×V{2,3}.
    Wing θ = [1,1,1,1,0,1,1,1,1]; tip-U θ = [1,1,1,1].
    """
    edges = [(0, 0), (0, 1), (1, 0), (1, 1),
             (2, 2), (2, 3), (3, 2), (3, 3), (1, 2)]
    return BipartiteGraph.from_edges(4, 4, edges)


def _nested():
    """K33 (θ=4) and K22 (θ=1) blobs + butterfly-free bridge (2,3).

    At level 1 the K33 component has no θ=1 edges, so its node is
    *collapsed* — the level-4 node hangs straight off the root.
    """
    e = [(u, v) for u in range(3) for v in range(3)]          # K33
    e += [(u, v) for u in (3, 4) for v in (3, 4)]             # K22
    e += [(2, 3)]                                             # bridge
    return BipartiteGraph.from_edges(5, 5, e)


def _level_components(h, k):
    """Components of the θ≥k subgraph from the packed forest, as the
    oracle's set-of-frozensets."""
    plev = np.where(h.parent >= 0, h.node_level[np.maximum(h.parent, 0)], -1)
    sel = np.where((h.node_level >= k) & (plev < k))[0]
    return {frozenset(int(e) for e in h.subtree_entities(x)) for x in sel}


def _lca_walk(h, x, y):
    """Brute-force LCA by parent walking."""
    anc = set()
    while x != -1:
        anc.add(x)
        x = int(h.parent[x])
    while y not in anc:
        y = int(h.parent[y])
    return y


# ------------------------------------------------------------------ golden
def test_golden_two_blobs_wing():
    g = _two_blobs()
    for engine in ("dense", "beindex", "csr"):
        h = build_hierarchy(g, wing_decomposition(g, P=3, engine=engine))
        assert np.array_equal(h.theta, [1, 1, 1, 1, 0, 1, 1, 1, 1])
        assert h.n_nodes == 3
        assert np.array_equal(h.node_level, [0, 1, 1])
        assert np.array_equal(h.parent, [-1, 0, 0])
        # the bridge edge is the root's only own member
        assert sorted(h.members(0)) == [4]
        subs = {frozenset(int(e) for e in h.subtree_entities(x))
                for x in (1, 2)}
        assert subs == {frozenset({0, 1, 2, 3}), frozenset({5, 6, 7, 8})}
        # both K22 leaves are complete bipartite: density 1
        assert np.allclose(h.density[1:], 1.0)
        assert h.meta["stats"]["engine"] == engine


def test_golden_two_blobs_tip():
    g = _two_blobs()
    for engine in ("dense", "csr"):
        res = tip_decomposition(g, side="u", P=3, engine=engine)
        h = build_hierarchy(g, res, kind="tip", side="u")
        assert np.array_equal(h.theta, [1, 1, 1, 1])
        assert h.n_nodes == 3
        assert np.array_equal(h.node_level, [0, 1, 1])
        subs = {frozenset(int(u) for u in h.subtree_entities(x))
                for x in (1, 2)}
        assert subs == {frozenset({0, 1}), frozenset({2, 3})}


def test_golden_nested_collapses_chain():
    g = _nested()
    res = wing_decomposition(g, P=4, engine="csr")
    h = build_hierarchy(g, res)
    # root + K22 node at level 1 + K33 node at level 4 — NO redundant
    # level-1 node around the K33 (its component there has no θ=1 edge)
    assert h.n_nodes == 3
    assert sorted(h.node_level.tolist()) == [0, 1, 4]
    assert np.array_equal(h.parent, [-1, 0, 0])
    k33 = int(np.where(h.node_level == 4)[0][0])
    assert h.node_m[k33] == 9 and h.node_nu[k33] == 3 and h.node_nv[k33] == 3
    assert h.density[k33] == 1.0
    # level profile at k=1 still shows BOTH blobs (collapsed node counts)
    prof = density_profile(h, 1)
    assert prof["n_components"] == 2
    assert sorted(prof["m"].tolist()) == [4, 9]


# ------------------------------------------------------- oracle + engines
@pytest.mark.parametrize("seed,nu,nv,m", [(3, 40, 30, 160), (7, 60, 40, 260)])
def test_wing_forest_matches_oracle_all_engines(seed, nu, nv, m):
    g = powerlaw_bipartite(nu, nv, m, seed=seed)
    results = {e: wing_decomposition(g, P=5, engine=e)
               for e in ("dense", "beindex", "csr")}
    forests = {e: build_hierarchy(g, r) for e, r in results.items()}
    want = ref.wing_hierarchy_ref(g, results["csr"].theta)
    for e, h in forests.items():
        for k, comps in want.items():
            assert _level_components(h, k) == comps, (e, k)
    hb = forests["beindex"]
    for h in (forests["dense"], forests["csr"]):
        assert np.array_equal(h.node_level, hb.node_level)
        assert np.array_equal(h.parent, hb.parent)
        assert np.array_equal(h.entity_node, hb.entity_node)
        assert np.array_equal(h.tin, hb.tin)


@pytest.mark.parametrize("side", ["u", "v"])
def test_tip_forest_matches_oracle(side):
    g = powerlaw_bipartite(50, 35, 200, seed=11)
    results = {e: tip_decomposition(g, side=side, P=4, engine=e)
               for e in ("dense", "csr")}
    forests = {e: build_hierarchy(g, r, kind="tip", side=side)
               for e, r in results.items()}
    want = ref.tip_hierarchy_ref(g, results["csr"].theta, side=side)
    for e, h in forests.items():
        for k, comps in want.items():
            assert _level_components(h, k) == comps, (e, k)
    assert np.array_equal(forests["dense"].parent, forests["csr"].parent)


def test_forest_invariants():
    g = powerlaw_bipartite(70, 45, 300, seed=5)
    h = build_hierarchy(g, wing_decomposition(g, P=6, engine="csr"))
    # parents precede children; levels strictly increase along edges
    assert np.all(h.parent[1:] < np.arange(1, h.n_nodes))
    assert np.all(h.node_level[1:] > h.node_level[h.parent[1:]])
    # member lists partition the entity set
    assert np.array_equal(np.sort(h.member_ids), np.arange(g.m))
    assert h.member_off[-1] == g.m
    # subtree slices nest: child range inside parent range
    for x in range(1, h.n_nodes):
        p = h.parent[x]
        assert h.estart[p] <= h.estart[x] and h.eend[x] <= h.eend[p]
    # every entity's own node carries its θ as level
    assert np.array_equal(h.node_level[h.entity_node], h.theta)


def test_label_components_is_single_while_loop():
    """The batched union-find must lower to ONE while op — a whole
    level block's components in a single device dispatch, no Python
    per-edge loops."""
    alive = np.ones((4, 16), dtype=bool)
    inc_e = np.arange(16, dtype=np.int32)
    inc_g = (np.arange(16, dtype=np.int32) // 2)
    lab0 = np.tile(np.arange(16, dtype=np.int32), (4, 1))
    jaxpr = jax.make_jaxpr(
        lambda a, l: _label_components(a, inc_e, inc_g, l, 16, 8)
    )(alive, lab0)
    assert str(jaxpr).count("while[") == 1


# ----------------------------------------------------------------- queries
def test_queries_match_oracle():
    g = powerlaw_bipartite(60, 40, 260, seed=7)
    res = wing_decomposition(g, P=5, engine="csr")
    h = build_hierarchy(g, res)
    f = pack_forest(h)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, g.m, 64)
    assert np.array_equal(np.asarray(max_k_containing(f, ids)),
                          res.theta[ids])
    assert np.array_equal(np.asarray(node_of(f, ids)), h.entity_node[ids])

    nodes = rng.integers(0, h.n_nodes, 8)
    masks = np.asarray(subgraph_at(f, nodes))
    for row, x in zip(masks, nodes):
        assert set(np.where(row)[0]) == set(h.subtree_entities(int(x)))

    e1 = rng.integers(0, g.m, 64)
    e2 = rng.integers(0, g.m, 64)
    got = np.asarray(lca_entities(f, e1, e2))
    for a, b, l in zip(e1, e2, got):
        assert l == _lca_walk(h, int(h.entity_node[a]),
                              int(h.entity_node[b])), (a, b)
    # lca of a node with itself / its ancestor
    x = int(nodes[0])
    assert int(np.asarray(lca_nodes(f, [x], [x]))[0]) == x
    assert int(np.asarray(lca_nodes(f, [x], [0]))[0]) == 0


def test_density_profile_and_top_leaves():
    g = powerlaw_bipartite(60, 40, 260, seed=7)
    res = wing_decomposition(g, P=5, engine="csr")
    h = build_hierarchy(g, res)
    for k in h.levels[:4]:
        prof = density_profile(h, int(k))
        want = ref.wing_hierarchy_ref(g, res.theta)[int(k)]
        assert prof["n_components"] == len(want)
        assert sorted(prof["sizes"].tolist()) == sorted(
            len(c) for c in want)
        # density really is m/(nu·nv) of the induced subgraph
        np.testing.assert_allclose(
            prof["density"], prof["m"] / (prof["nu"] * prof["nv"]))
    top = top_densest_leaves(h, 5)
    leaf = np.diff(h.child_off) == 0
    assert all(leaf[x] for x in top["nodes"])
    d = top["density"]
    assert np.all(d[:-1] >= d[1:])


# ------------------------------------------------------------- serialization
def test_serialize_roundtrip():
    g = powerlaw_bipartite(50, 30, 200, seed=2)
    res = wing_decomposition(g, P=4, engine="csr")
    h = build_hierarchy(g, res)
    buf = io.BytesIO()
    save_hierarchy(buf, h)
    buf.seek(0)
    h2 = load_hierarchy(buf)
    assert h2.kind == h.kind and h2.n_entities == h.n_entities
    for f in ("theta", "node_level", "parent", "entity_node", "member_off",
              "member_ids", "child_off", "child_ids", "tin", "tout",
              "ent_order", "estart", "eend", "node_m", "node_nu",
              "node_nv", "density"):
        assert np.array_equal(getattr(h2, f), getattr(h, f)), f
    # provenance arrays survive too
    assert np.array_equal(h2.meta["part"], res.part)
    assert np.array_equal(h2.meta["ranges"], res.ranges)
    # queries on the reloaded artifact are identical
    f1, f2 = pack_forest(h), pack_forest(h2)
    ids = np.arange(g.m)
    assert np.array_equal(np.asarray(lca_entities(f1, ids, ids[::-1])),
                          np.asarray(lca_entities(f2, ids, ids[::-1])))


def test_serialize_version_guard():
    g = _two_blobs()
    h = build_hierarchy(g, wing_decomposition(g, P=2, engine="csr"))
    buf = io.BytesIO()
    import repro.hierarchy.serialize as S
    old_ver, old_sup = S.FORMAT_VERSION, S._SUPPORTED_VERSIONS
    try:
        # simulate a FUTURE build writing a layout this one never heard
        # of; the loader (restored constants) must refuse it
        S.FORMAT_VERSION = 99
        S._SUPPORTED_VERSIONS = old_sup + (99,)
        save_hierarchy(buf, h, version=99)
    finally:
        S.FORMAT_VERSION, S._SUPPORTED_VERSIONS = old_ver, old_sup
    buf.seek(0)
    with pytest.raises(ValueError, match="format"):
        load_hierarchy(buf)


def test_peelstats_roundtrip_through_serializer():
    """Regression (bugfix hygiene): the engine / fd_driver provenance
    tags of PeelStats.as_dict() must survive the artifact round-trip,
    and from_dict must invert as_dict despite the derived keys."""
    g = powerlaw_bipartite(40, 25, 150, seed=9)
    for engine, fd_driver in (("csr", "device"), ("csr", "host"),
                              ("beindex", "host")):
        res = wing_decomposition(g, P=3, engine=engine, fd_driver=fd_driver)
        h = build_hierarchy(g, res)
        buf = io.BytesIO()
        save_hierarchy(buf, h)
        buf.seek(0)
        got = load_hierarchy(buf).meta["stats"]
        assert got == res.stats.as_dict()
        st = PeelStats.from_dict(got)
        assert st == res.stats
        assert (st.engine, st.fd_driver) == (engine, res.stats.fd_driver)


# ----------------------------------------------------------------- service
def test_service_mixed_batch_matches_direct():
    g = powerlaw_bipartite(60, 40, 260, seed=7)
    res = wing_decomposition(g, P=5, engine="csr")
    h = build_hierarchy(g, res)
    f = pack_forest(h)
    svc = HierarchyService(h, batch=64)
    rng = np.random.default_rng(1)
    queries = []
    for i in range(200):  # deliberately not a multiple of the batch size
        op = ["max_k", "node_of", "lca_node", "lca_level",
              "subtree_size"][i % 5]
        a = int(rng.integers(0, h.n_nodes if op == "subtree_size" else g.m))
        b = int(rng.integers(0, g.m))
        queries.append(HQuery(uid=i, op=op, a=a, b=b))
        svc.submit(queries[-1])
    done = svc.run()
    assert [q.uid for q in done] == list(range(200))
    assert svc.served == 200 and svc.pending() == 0
    for q in done:
        if q.op == "max_k":
            want = int(res.theta[q.a])
        elif q.op == "node_of":
            want = int(h.entity_node[q.a])
        elif q.op == "lca_node":
            want = _lca_walk(h, int(h.entity_node[q.a]),
                             int(h.entity_node[q.b]))
        elif q.op == "lca_level":
            want = int(h.node_level[_lca_walk(
                h, int(h.entity_node[q.a]), int(h.entity_node[q.b]))])
        else:
            want = int(h.eend[q.a] - h.estart[q.a])
        assert q.result == want, (q.uid, q.op)
    # mask-shaped queries via the dedicated entry point
    masks = svc.subgraph_masks(np.asarray([0, 1]))
    assert masks.shape == (2, g.m) and masks[0].all()
    assert np.array_equal(masks, np.asarray(subgraph_at(f, [0, 1])))


def test_service_rejects_unknown_op():
    g = _two_blobs()
    h = build_hierarchy(g, wing_decomposition(g, P=2, engine="csr"))
    svc = HierarchyService(h)
    with pytest.raises(ValueError, match="unknown op"):
        svc.submit(HQuery(uid=0, op="nope", a=0))


def test_service_rejects_out_of_range_ids():
    """Jitted gathers clamp out-of-range indices — without a host-side
    bounds check a malformed client id would yield a confidently wrong
    answer instead of an error."""
    g = _two_blobs()
    h = build_hierarchy(g, wing_decomposition(g, P=2, engine="csr"))
    svc = HierarchyService(h)
    with pytest.raises(ValueError, match="out of range"):
        svc.submit(HQuery(uid=0, op="max_k", a=g.m + 5))
    with pytest.raises(ValueError, match="out of range"):
        svc.submit(HQuery(uid=0, op="lca_node", a=0, b=-1))
    with pytest.raises(ValueError, match="out of range"):
        svc.submit(HQuery(uid=0, op="subtree_size", a=h.n_nodes))
    # node-arg op accepts node ids past n_entities (n_nodes may exceed it)
    svc.submit(HQuery(uid=1, op="subtree_size", a=h.n_nodes - 1))
    with pytest.raises(ValueError, match="out of range"):
        svc.query_batch(np.asarray([0]), np.asarray([g.m]))
    with pytest.raises(ValueError, match="out of range"):
        svc.subgraph_masks(np.asarray([h.n_nodes]))
    # the valid query still serves: last node is a K22 leaf (4 edges)
    assert svc.run()[0].result == 4


def test_save_writes_exact_path(tmp_path):
    """np.savez silently appends '.npz' to suffix-less string paths;
    save_hierarchy must land the artifact exactly where asked."""
    g = _two_blobs()
    h = build_hierarchy(g, wing_decomposition(g, P=2, engine="csr"))
    p = tmp_path / "artifact_no_suffix"
    save_hierarchy(str(p), h)
    assert p.exists() and not (tmp_path / "artifact_no_suffix.npz").exists()
    assert np.array_equal(load_hierarchy(str(p)).parent, h.parent)


def test_empty_and_degenerate_graphs():
    # no edges at all: the forest is just the root
    g = BipartiteGraph.from_edges(3, 3, np.zeros((0, 2), np.int32))
    h = build_hierarchy(g, wing_decomposition(g, P=2))
    assert h.n_nodes == 1 and h.n_entities == 0
    # node-arg queries still serve on an entity-less hierarchy — the
    # batch padding must not trip the bounds check (regression)
    svc = HierarchyService(h, batch=8)
    svc.submit(HQuery(uid=0, op="subtree_size", a=0))
    assert svc.run()[0].result == 0
    # butterfly-free graph: every edge is a root member
    g = BipartiteGraph.from_edges(2, 2, [[0, 0], [1, 1]])
    h = build_hierarchy(g, wing_decomposition(g, P=2))
    assert h.n_nodes == 1
    assert sorted(h.members(0)) == [0, 1]
    f = pack_forest(h)
    assert int(np.asarray(lca_entities(f, [0], [1]))[0]) == 0
