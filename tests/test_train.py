"""Training substrate: optimizer, microbatching, checkpoint/restart
(incl. crash injection), elastic re-meshing, straggler detection."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import get_config
from repro.models.config import reduced
from repro.train import (
    AdamWConfig,
    StragglerDetector,
    TrainConfig,
    latest_step,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import adamw_init

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(arch="tinyllama_1_1b", **kw):
    cfg = reduced(get_config(arch), **kw)
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = dict(
        tokens=jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (4, 32)),
            jnp.int32),
        labels=jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (4, 32)),
            jnp.int32),
    )
    return cfg, params, batch


def test_train_step_reduces_loss():
    cfg, params, batch = _setup()
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, TrainConfig(opt=AdamWConfig(lr=5e-3, total_steps=50))))
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
    assert int(opt.step) == 12


def test_microbatching_matches_full_batch():
    cfg, params, batch = _setup()
    opt = adamw_init(params)
    s1 = make_train_step(cfg, TrainConfig(microbatches=1))
    s2 = make_train_step(cfg, TrainConfig(microbatches=2))
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    a = jax.tree.leaves(p1)[0]
    b = jax.tree.leaves(p2)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-4)


def test_grad_compression_still_trains():
    cfg, params, batch = _setup()
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, TrainConfig(compress_grads=True,
                         opt=AdamWConfig(lr=5e-3, total_steps=50))))
    losses = []
    for _ in range(10):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_checkpoint_roundtrip(tmp_path):
    cfg, params, batch = _setup()
    opt = adamw_init(params)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, 7, params, opt, extra=dict(arch=cfg.name))
    assert latest_step(path) == 7
    p2, o2, man = restore_checkpoint(path, 7, params, opt)
    assert man["extra"]["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2.step) == int(opt.step)


def test_incomplete_checkpoint_invisible(tmp_path):
    cfg, params, batch = _setup()
    opt = adamw_init(params)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, 3, params, opt)
    # simulate a crash mid-save at step 9: directory without manifest
    os.makedirs(os.path.join(path, "step_00000009"))
    assert latest_step(path) == 3


def test_crash_and_resume(tmp_path):
    """Kill training mid-run; resume must continue from the checkpoint
    and finish with the same data order (bit-reproducible pipeline)."""
    ckpt = str(tmp_path / "ckpt")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "tinyllama_1_1b", "--reduced",
            "--steps", "30", "--batch", "4", "--seq", "32",
            "--ckpt-dir", ckpt, "--ckpt-every", "10",
            "--log-every", "5"]
    out1 = subprocess.run(args + ["--crash-at", "15"],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert out1.returncode == 42, out1.stderr[-1500:]
    assert latest_step(ckpt) == 10
    out2 = subprocess.run(args, env=env, capture_output=True, text=True,
                          timeout=900)
    assert out2.returncode == 0, out2.stderr[-1500:]
    assert "resumed from step 10" in out2.stdout
    assert latest_step(ckpt) == 30


def test_elastic_remesh_subprocess():
    """Restore state onto a different device count (pod loss): 8 -> 4.

    Imports ``AxisType`` through ``repro.sharding.compat`` (the pinned
    jax<0.5 has no ``jax.sharding.AxisType``; the shim provides the
    sentinel enum there and the real one on newer jax)."""
    import textwrap
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.compat import AxisType
        assert hasattr(AxisType, "Auto")
        import repro.models as M
        from repro.configs import get_config
        from repro.models.config import reduced
        from repro.train.optimizer import adamw_init
        from repro.train.elastic import remesh
        cfg = reduced(get_config("tinyllama_1_1b"))
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        opt = adamw_init(params)
        axes = M.logical_axes(cfg)
        devs = np.array(jax.devices())
        m8 = jax.sharding.Mesh(devs.reshape(2, 4), ("data", "model"))
        p8, o8 = remesh(params, opt, axes, m8)
        # lose half the devices
        m4 = jax.sharding.Mesh(devs[:4].reshape(2, 2), ("data", "model"))
        p4, o4 = remesh(p8, o8, axes, m4)
        a = np.asarray(jax.tree.leaves(params)[0])
        b = np.asarray(jax.tree.leaves(p4)[0])
        assert np.array_equal(a, b)
        print("ELASTIC_OK")
    """)
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC_OK" in out.stdout


def test_straggler_detector():
    det = StragglerDetector(alpha=0.5, threshold_sigma=1.0)
    import time
    for _ in range(5):
        det.start()
        time.sleep(0.01)
        det.stop()
    det.start()
    time.sleep(0.08)
    assert det.stop() is True


def test_data_pipeline_determinism():
    from repro.data import DataConfig, synthetic_batches
    cfg = DataConfig(batch=4, seq=16, vocab=100, seed=3)
    a = next(synthetic_batches(cfg, start_step=5))
    b = next(synthetic_batches(cfg, start_step=5))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(synthetic_batches(cfg, start_step=6))
    assert not np.array_equal(a["tokens"], c["tokens"])
