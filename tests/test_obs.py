"""Observability layer (``src/repro/obs``).

Four claims under test:

* **Units** — tracer span/instant/counter recording, Chrome-trace
  export shape, metrics registry snapshots, timeline (de)serialization.
* **Exact-match oracle** — with the layer enabled, per-phase trace
  span counts equal the run's :class:`PeelStats` *exactly*
  (``cd.round`` count == ``rho_cd``, ``fd.round`` count ==
  ``rho_fd_total``), across engines and FD drivers, single-node and
  distributed; and enabling telemetry never changes θ.
* **Serving metrics oracle** — pool cache counters mirror the pool's
  plain-int LRU bookkeeping one-for-one; per-slot admission upload is
  bit-identical to the whole-bucket re-upload it replaces.
* **Graceful shutdown** — ``launch/hserve.py`` under SIGINT drains the
  queue, flushes the metrics snapshot, and exits 0 (subprocess
  regression); the snapshot's cache counts match the ``--out`` oracle.

The zero-overhead-off guarantee (byte-identical jaxprs with telemetry
disabled) is asserted against ``tests/goldens/obs_jaxprs.json`` in
``test_fused_fd.py`` / ``test_multiserve.py`` /
``test_core_distributed.py`` next to the structural invariants those
suites already state.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro import obs
from repro.core.graph import powerlaw_bipartite, random_bipartite
from repro.core.peel import tip_decomposition, wing_decomposition
from repro.hierarchy import (
    ForestPool,
    MultiTenantService,
    build_hierarchy,
    save_hierarchy,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def tracer():
    """A fresh tracer per test; always disabled afterwards so the rest
    of the suite keeps the zero-overhead default path."""
    obs.disable()
    t = obs.enable()
    yield t
    obs.disable()


# =====================================================================
# units: tracer
# =====================================================================
def test_tracer_records_and_exports(tracer, tmp_path):
    with obs.span("outer", cat="peel", kind="wing"):
        with obs.span("inner", cat="cd") as sp:
            sp.update(died=3, frontier=7)
        obs.instant("tick", cat="fd.round", part=0)
        obs.counter("curve", {"frontier": 7})
    assert tracer.count("peel") == 1
    assert tracer.count("cd") == 1
    assert tracer.count("fd.round", ph="i") == 1
    assert tracer.count(ph="C") == 1
    # late args land on the span event
    (inner,) = tracer.spans("cd")
    assert inner["args"] == {"died": 3, "frontier": 7}
    assert inner["dur"] >= 0
    # nesting: outer span encloses inner on the timeline
    (outer,) = tracer.spans("peel")
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert tracer.sum_arg("died", cat="cd") == 3
    # chrome envelope: standard keys, JSON-serializable, round-trips
    path = str(tmp_path / "trace.json")
    tracer.save(path)
    with open(path) as f:
        chrome = json.load(f)
    assert set(chrome) == {"traceEvents", "displayTimeUnit"}
    assert len(chrome["traceEvents"]) == 4
    for ev in chrome["traceEvents"]:
        assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(ev)
        if ev["ph"] == "X":
            assert "dur" in ev


def test_disabled_layer_is_inert():
    obs.disable()
    assert not obs.enabled()
    assert obs.get_tracer() is None
    with obs.span("ghost", cat="peel") as sp:
        assert sp is None
    obs.instant("ghost")
    obs.counter("ghost", {"x": 1})
    with obs.maybe_collect() as col:
        assert col is None
        assert obs.fd_ring_cap() == 0


def test_ring_cap_env(tracer, monkeypatch):
    with obs.maybe_collect():
        assert obs.fd_ring_cap() == obs.RING_CAP_DEFAULT
        monkeypatch.setenv("REPRO_OBS_RING_CAP", "17")
        assert obs.fd_ring_cap() == 17
        monkeypatch.setenv("REPRO_OBS_RING_CAP", "bogus")
        assert obs.fd_ring_cap() == obs.RING_CAP_DEFAULT
    assert obs.fd_ring_cap() == 0        # no live collector


# =====================================================================
# units: metrics
# =====================================================================
def test_metrics_registry_snapshot(tmp_path):
    reg = obs.MetricsRegistry()
    reg.inc("ops")
    reg.inc("ops", 4)
    reg.set_gauge("depth", 3)
    reg.set_gauge("depth", 9)
    for ms in (0.5, 1.0, 2.0, 4.0, 400.0):
        reg.observe("lat", ms)
    reg.histogram("empty")
    snap = reg.snapshot()
    assert snap["ops"] == {"type": "counter", "value": 5}
    assert snap["depth"] == {"type": "gauge", "value": 9.0}
    assert snap["empty"] == {"type": "histogram", "count": 0}
    lat = snap["lat"]
    assert lat["count"] == 5
    assert lat["sum_ms"] == pytest.approx(407.5)
    assert lat["min_ms"] == 0.5 and lat["max_ms"] == 400.0
    # percentiles are bucket-interpolated but clamped and ordered
    assert lat["min_ms"] <= lat["p50_ms"] <= lat["p99_ms"] <= lat["max_ms"]
    path = str(tmp_path / "metrics.json")
    reg.save(path)
    with open(path) as f:
        assert json.load(f) == snap
    with pytest.raises(TypeError):
        reg.observe("ops", 1.0)          # name already bound to a counter


def test_percentiles_exact():
    samples = list(range(101))           # 0..100
    ps = obs.percentiles(samples)
    assert ps == {"p50": 50.0, "p99": 99.0}
    assert obs.percentiles([]) == {"p50": 0.0, "p99": 0.0}
    one = obs.percentiles([7.0], ps=(50.0, 90.0, 99.0))
    assert one == {"p50": 7.0, "p90": 7.0, "p99": 7.0}


# =====================================================================
# units: timeline
# =====================================================================
def test_timeline_collector_and_roundtrip():
    col = obs.TimelineCollector()
    col.record_cd_round(0, died=5, frontier=20, hi=3, updates=12,
                        recounts=2)
    col.record_cd_round(1, died=20, frontier=0, hi=9, updates=7,
                        recounts=0)
    col.record_fd_host(0, [dict(died=2, frontier=3, k=1),
                           dict(died=3, frontier=0, k=2)])
    rings = (np.array([4, 1, 0]), np.array([6, 0, 0]),
             np.array([1, 2, 0]), np.array([[8], [3], [0]]))
    col.record_fd_rings("device", parts=[1], rounds=[2], rings=rings,
                        cap=3)
    col.record_fd_counts("sharded", parts=[0, 1, 2], rounds=[3, 0, 4])
    tl = col.build()
    assert tl.cd_rounds == 2
    assert tl.fd_rounds_total() == 2 + 2 + 7
    assert tl.fd_rounds_max() == 4
    assert tl.updates_total() == 12 + 7 + 8 + 3
    assert not tl.truncated()
    s = tl.summary()
    assert s["cd_rounds"] == 2 and s["fd_launches"] == 3
    assert s["fd_rounds_total"] == 11 and s["cd_died_max"] == 20
    # counts-only launches have no per-round detail (T == 0)
    assert tl.fd[2]["died"].shape == (0, 3)
    # dict round trip preserves every total
    tl2 = obs.PeelTimeline.from_dict(
        json.loads(json.dumps(tl.as_dict())))
    assert tl2.cd_rounds == tl.cd_rounds
    assert tl2.fd_rounds_total() == tl.fd_rounds_total()
    assert tl2.updates_total() == tl.updates_total()
    assert tl2.summary() == s


def test_timeline_ring_truncation():
    col = obs.TimelineCollector()
    rings = (np.array([1, 1]), np.array([9, 0]),
             np.array([1, 5]), np.array([[2], [2]]))
    col.record_fd_rings("device", parts=[0], rounds=[5], rings=rings,
                        cap=2)
    tl = col.build()
    assert tl.truncated()
    assert tl.fd_rounds_total() == 5     # round totals stay exact
    assert tl.fd[0]["died"].shape == (2, 1)


# =====================================================================
# the exact-match oracle: span counts == PeelStats, θ unchanged
# =====================================================================
WING_COMBOS = [
    ("beindex", "device", False),
    ("beindex", "host", False),
    ("csr", "device", False),
    ("csr", "vmapped", False),
    ("csr", "device", True),             # fused
]
TIP_COMBOS = [
    ("dense", "device", False),
    ("dense", "host", False),
    ("csr", "device", False),
    ("csr", "vmapped", False),
    ("csr", "device", True),             # fused
]


def _assert_exact_match(run):
    """θ with telemetry on == θ off; trace counts == PeelStats."""
    obs.disable()
    base = run()
    t = obs.enable()
    try:
        res = run()
    finally:
        obs.disable()
    np.testing.assert_array_equal(res.theta, base.theta)
    st = res.stats
    assert res.timeline is not None
    assert res.timeline.cd_rounds == st.rho_cd
    assert res.timeline.fd_rounds_total() == st.rho_fd_total
    assert t.count("cd.round", ph="X") == st.rho_cd
    assert t.count("fd.round", ph="i") == st.rho_fd_total
    assert t.count("peel", ph="X") == 1
    assert t.count("cd", ph="X") == 1
    assert t.count("fd", ph="X") == 1
    assert res.provenance()["timeline"]["cd_rounds"] == st.rho_cd


@pytest.mark.parametrize("engine,fd_driver,fused", WING_COMBOS)
def test_wing_trace_counts_match_stats(engine, fd_driver, fused):
    g = random_bipartite(30, 24, 140, seed=1)
    _assert_exact_match(
        lambda: wing_decomposition(g, P=4, engine=engine,
                                   fd_driver=fd_driver, fused=fused))


@pytest.mark.parametrize("engine,fd_driver,fused", TIP_COMBOS)
def test_tip_trace_counts_match_stats(engine, fd_driver, fused):
    g = random_bipartite(30, 24, 140, seed=1)
    _assert_exact_match(
        lambda: tip_decomposition(g, side="u", P=4, engine=engine,
                                  fd_driver=fd_driver, fused=fused))


def test_distributed_trace_counts_match_stats():
    """8-device wing+tip with telemetry on: the sharded FD records
    counts-only launches, but totals must still equal PeelStats and the
    info dict must carry the timeline summary (subprocess for the
    forced host device count)."""
    src = """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro import obs
        from repro.core.graph import random_bipartite
        from repro.core import distributed as D
        obs.enable()
        tr = obs.get_tracer()
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = random_bipartite(60, 40, 260, seed=3)
        for kind, fn, kw in (
            ("wing", D.distributed_wing_decomposition,
             dict(engine="csr")),
            ("tip", D.distributed_tip_decomposition,
             dict(side="u", engine="csr")),
        ):
            n0_cd = tr.count("cd.round", ph="X")
            n0_fd = tr.count("fd.round", ph="i")
            theta, info, res = fn(g, mesh, P_parts=8,
                                  return_result=True, **kw)
            st = res.stats
            tl = res.timeline
            assert tl is not None, kind
            assert info["timeline"] == tl.summary(), kind
            assert tl.cd_rounds == st.rho_cd, kind
            assert tl.fd_rounds_total() == st.rho_fd_total, kind
            d_cd = tr.count("cd.round", ph="X") - n0_cd
            d_fd = tr.count("fd.round", ph="i") - n0_fd
            assert d_cd == st.rho_cd, (kind, d_cd, st.rho_cd)
            assert d_fd == st.rho_fd_total, (kind, d_fd, st.rho_fd_total)
        print("DIST-OBS-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DIST-OBS-OK" in out.stdout


# =====================================================================
# serving metrics: the pool LRU oracle + per-slot admission parity
# =====================================================================
def _hier(nu=40, nv=28, m=120, seed=0):
    g = powerlaw_bipartite(nu, nv, m, seed=seed)
    return build_hierarchy(g, wing_decomposition(g, P=4, engine="csr"))


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("obs_tenants")
    hs = [_hier(seed=i) for i in range(5)]
    for i, h in enumerate(hs):
        save_hierarchy(str(d / f"t{i}.npz"), h)
    # same decomposition under a second name: guaranteed same shape
    # bucket as t0 (the slot-upload parity test relies on this)
    save_hierarchy(str(d / "dup0.npz"), hs[0])
    return str(d)


def test_pool_metrics_match_lru_oracle(art_dir):
    pool = ForestPool(slots=3, artifact_dir=art_dir)
    # misses t0..t2 fill the pool; t0/t1 hits re-rank them; t3 and t4
    # evict; the final t2 re-load is a miss evicting again
    for t in ("t0", "t1", "t2", "t0", "t1", "t3", "t4", "t2"):
        pool.ensure(t)
    assert (pool.hits, pool.misses, pool.evictions) == (2, 6, 3)
    snap = pool.metrics.snapshot()
    assert snap["pool.hits"]["value"] == pool.hits
    assert snap["pool.misses"]["value"] == pool.misses
    assert snap["pool.evictions"]["value"] == pool.evictions
    assert snap["pool.resident"]["value"] == pool.resident_count == 3
    assert snap["pool.load_ms"]["count"] == pool.misses
    # the plain-int stats dict and the registry never diverge
    st = pool.stats()
    for key in ("hits", "misses", "evictions"):
        assert snap[f"pool.{key}"]["value"] == st[key]


def test_service_shares_pool_registry(art_dir):
    pool = ForestPool(slots=4, artifact_dir=art_dir)
    svc = MultiTenantService(pool, batch=32)
    assert svc.metrics is pool.metrics
    n = 80
    rng = np.random.default_rng(0)
    tenants = [("t0", "t1")[i % 2] for i in range(n)]
    ops = np.zeros(n, np.int32)          # op 0 needs only entity ids
    a = rng.integers(0, 10, n).astype(np.int32)
    svc.query_batch(tenants, ops, a)
    snap = svc.metrics.snapshot()
    assert snap["serve.served"]["value"] == n
    assert snap["serve.dispatches"]["value"] == svc.dispatches
    assert snap["serve.dispatch_ms"]["count"] == svc.dispatches
    assert snap["serve.tenant.t0"]["value"] == n // 2
    assert snap["serve.tenant.t1"]["value"] == n // 2
    # padded slots: per dispatch, batch - served_in_chunk
    padded = snap["serve.slots_padded"]["value"]
    assert padded == svc.dispatches * 32 - n


def test_slot_admission_parity(art_dir):
    """Per-slot dynamic_update_slice admission must leave device arrays
    bit-identical to the whole-bucket re-upload path, and identical to
    the host mirror."""
    arrs = {}
    pools = {}
    for mode, su in (("slot", True), ("bucket", False)):
        pool = ForestPool(slots=8, artifact_dir=art_dir, slot_upload=su)
        pool.ensure("t0")
        for key in list(pool.buckets):
            pool.bucket_arrays(key)      # device-resident before admit
        pool.ensure("dup0")              # same bucket as t0 by design
        arrs[mode] = {
            key: {n: np.asarray(a)
                  for n, a in pool.bucket_arrays(key).items()}
            for key in pool.buckets
        }
        pools[mode] = pool
    assert arrs["slot"].keys() == arrs["bucket"].keys()
    for key in arrs["slot"]:
        for name in arrs["slot"][key]:
            np.testing.assert_array_equal(
                arrs["slot"][key][name], arrs["bucket"][key][name])
    for key, bucket in pools["slot"].buckets.items():
        for name, host in bucket.host.items():
            np.testing.assert_array_equal(
                np.asarray(bucket.device[name]), host)
    # the slot path observed an admission upload; the bucket path paid
    # a re-upload instead
    m_slot = pools["slot"].metrics.get("pool.admission_upload_ms")
    assert m_slot is not None and m_slot.count == 1
    assert pools["bucket"].metrics.get(
        "pool.admission_upload_ms") is None
    m_re = pools["bucket"].metrics.get("pool.bucket_upload_ms")
    assert m_re is not None and m_re.count >= 2


# =====================================================================
# hserve graceful shutdown (subprocess regression)
# =====================================================================
def test_hserve_sigint_graceful_exit(art_dir, tmp_path):
    """SIGINT mid-serve: drains, flushes metrics, exits 0; the metrics
    snapshot's cache counts match the ``--out`` LRU oracle."""
    metrics_path = str(tmp_path / "metrics.json")
    out_path = str(tmp_path / "out.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["PYTHONUNBUFFERED"] = "1"
    # a workload too large to finish before the signal: exit 0 can only
    # mean the graceful path ran (the handler is installed right after
    # the warm print, so any SIGINT from then on is honored)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.hserve",
         "--artifact-dir", art_dir, "--pool-slots", "4",
         "--batch", "64", "--queries", "2000000",
         "--metrics", metrics_path, "--out", out_path],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True,
    )
    try:
        head = []
        for line in proc.stdout:         # unbuffered: arrives live
            head.append(line)
            if "warmed" in line:
                break
        assert any("warmed" in ln for ln in head), "".join(head)
        time.sleep(0.5)
        proc.send_signal(signal.SIGINT)
        stdout, stderr = proc.communicate(timeout=300)
        stdout = "".join(head) + stdout
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, (stdout[-2000:], stderr[-2000:])
    assert "shutdown signal: queue drained" in stdout
    with open(out_path) as f:
        oracle = json.load(f)
    assert oracle["served"] < 2_000_000           # actually interrupted
    with open(metrics_path) as f:
        snap = json.load(f)
    for key in ("hits", "misses", "evictions"):
        # a counter never incremented is absent from the registry == 0
        got = snap.get(f"pool.{key}", {}).get("value", 0)
        assert got == oracle[key], key
    assert snap["pool.resident"]["value"] == oracle["resident"]
    assert "serve.qps" in snap
