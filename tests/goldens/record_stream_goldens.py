#!/usr/bin/env python
"""Record the streaming-updater goldens (``stream_goldens.json``).

Each case replays a fixed seeded event trace through
:class:`repro.streaming.StreamState` and records, per epoch, integer
digests of everything the updater maintains: a sha256 of θ, the full
``PeelStats.as_dict()`` row, a sha256 over every packed-forest array,
and the dirty-partition / dirty-level counts.  All of it is derived
from integer peeling (and exact float division for densities), so the
digests are machine-independent — unlike the jaxpr goldens they carry
no jax-version stamp.

``tests/test_streaming.py`` replays the same traces and asserts every
digest, locking BOTH invariants at once: the incremental path stays
bit-identical to itself across refactors, and (because the recorder
ran against a tree whose differential harness proved incremental ≡
from-scratch) to a full re-peel.  Re-record only when peel semantics
intentionally change:

    PYTHONPATH=src python tests/goldens/record_stream_goldens.py

The case builders are imported by the test so recorded and replayed
runs come from identical inputs.
"""
from __future__ import annotations

import hashlib
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN_PATH = os.path.join(HERE, "stream_goldens.json")

FOREST_FIELDS = (
    "node_level", "parent", "entity_node", "member_off", "member_ids",
    "child_off", "child_ids", "tin", "tout", "ent_order", "estart",
    "eend", "node_m", "node_nu", "node_nv",
)

# name -> (kind, engine, fd_driver, P, (n_u, n_v, m, graph_seed),
#          epochs, batch, event_seed)
CASES = {
    "wing_csr_device": ("wing", "csr", "device", 8, (80, 50, 400, 5),
                        4, 20, 200),
    "tip_csr_device": ("tip", "csr", "device", 8, (80, 50, 400, 5),
                       3, 16, 300),
    "wing_dense_host": ("wing", "dense", "host", 8, (60, 40, 260, 3),
                        3, 14, 400),
}


def _sha(arr) -> str:
    import numpy as np

    a = np.ascontiguousarray(arr)
    return hashlib.sha256(
        a.astype(np.int64, copy=False).tobytes()).hexdigest()[:16]


def forest_digest(h) -> str:
    """One digest over every packed-forest array (ints only — density
    is a derived ratio of the int fields, so it adds no information)."""
    import numpy as np

    hsh = hashlib.sha256()
    for f in FOREST_FIELDS:
        hsh.update(f.encode())
        hsh.update(np.ascontiguousarray(
            getattr(h, f)).astype(np.int64, copy=False).tobytes())
    return hsh.hexdigest()[:16]


def replay(name: str):
    """Run one case; yields the per-epoch golden record."""
    from repro.core.graph import powerlaw_bipartite
    from repro.streaming import StreamConfig, StreamState, \
        make_random_events

    kind, engine, fd_driver, P, gspec, epochs, batch, seed = CASES[name]
    n_u, n_v, m, gseed = gspec
    g = powerlaw_bipartite(n_u, n_v, m, seed=gseed)
    st = StreamState.initial(
        g, StreamConfig(kind=kind, engine=engine, P=P,
                        fd_driver=fd_driver))
    for e in range(epochs):
        events = make_random_events(st.g, batch, seed=seed + e)
        rep = st.apply_epoch(events)
        yield dict(
            epoch=rep.epoch,
            net=[rep.n_inserts, rep.n_deletes],
            m=int(st.g.m),
            theta_sha=_sha(st.result.theta),
            part_sha=_sha(st.result.part),
            sup_init_sha=_sha(st.result.support_init),
            stats=st.result.stats.as_dict(),
            forest_sha=forest_digest(st.hierarchy),
            partitions_dirty=rep.partitions_dirty,
            levels_dirty=rep.levels_dirty,
        )


def main() -> None:
    golden = {"schema": 1, "cases": {}}
    for name in CASES:
        rows = list(replay(name))
        golden["cases"][name] = rows
        print(f"[record-stream] {name}: {len(rows)} epochs, final "
              f"theta_sha={rows[-1]['theta_sha']}")
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1)
    print(f"[record-stream] wrote {len(golden['cases'])} cases -> "
          f"{GOLDEN_PATH}")


if __name__ == "__main__":
    main()
