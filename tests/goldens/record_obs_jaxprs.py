#!/usr/bin/env python
"""Record the telemetry-off reference jaxprs for the observability layer.

The obs subsystem (``src/repro/obs/``) carries a hard guarantee:
**telemetry off produces byte-identical jaxprs** — the counter-ring
instrumentation threaded through the FD loop carries must be a
trace-time branch that, when disabled (the default), leaves the traced
program literally unchanged.  This script records the reference texts
the assertion suites compare against:

* fused FD (wing + tip): the whole cascade, body = one ``pallas_call``;
* vmapped FD (wing + tip): the whole Phase 2 as ONE ``while_loop``;
* one-psum pair-aligned CD round (8-device shard_map, subprocess);
* the multiserve batched dispatch (loop/collective-free).

It was run ONCE at the pre-instrumentation tree to produce
``tests/goldens/obs_jaxprs.json``; the suites re-derive the same
jaxprs from the instrumented tree (telemetry disabled) and assert
byte-equality (``tests/test_fused_fd.py``, ``tests/test_multiserve.py``,
``tests/test_core_distributed.py``).  Re-record only when a jaxpr is
*intentionally* changed on the default path:

    PYTHONPATH=src python tests/goldens/record_obs_jaxprs.py

The case builders below are imported by the assertion suites so the
recorded and re-derived jaxprs come from identical inputs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(os.path.dirname(HERE))
GOLDEN_PATH = os.path.join(HERE, "obs_jaxprs.json")

# the 8-device subprocess case: the pair-aligned one-psum CD round.
# Kept as source so the recorder and test_core_distributed.py run the
# EXACT same program (the test pipes it through its own _run helper).
CD_PAIR_ALIGNED_SRC = """
    import numpy as np, jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.graph import powerlaw_bipartite
    from repro.core import csr
    from repro.core import distributed as D
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
    g = powerlaw_bipartite(80, 40, 350, seed=2)
    wed = csr.build_wedges(g)
    packed = D.shard_wedges_pair_aligned(wed, 8)
    fn = D.make_cd_round_csr_pair_aligned(
        mesh, "peel", packed["Pmax"], g.m)
    peeled = jnp.zeros((g.m + 1,), bool)
    sup = jnp.zeros((g.m + 1,), jnp.int32)
    jaxpr = str(jax.make_jaxpr(fn)(
        peeled, jnp.asarray(packed["alive"]), jnp.asarray(packed["W0"]),
        sup, jnp.asarray(packed["we1"]), jnp.asarray(packed["we2"]),
        jnp.asarray(packed["wp"])))
    print(jaxpr.strip())
"""


def _wing_pack():
    import numpy as np

    from repro.core import csr
    from repro.core.distributed import pack_fd_partitions_csr
    from repro.core.graph import random_bipartite
    from repro.core.peel import wing_decomposition

    g = random_bipartite(30, 24, 140, seed=0)
    wed = csr.build_wedges(g)
    res = wing_decomposition(g, P=4, engine="csr")
    n_parts = int(res.part.max()) + 1
    slotted = pack_fd_partitions_csr(
        wed, res.part, res.support_init, n_parts, bucket=True, slots=True)
    R, _ = slotted["slot_sizes"]
    W_rows = np.zeros((n_parts, R), np.int32)
    w = min(R, slotted["W0"].shape[1])
    W_rows[:, :w] = slotted["W0"][:, :w]
    slotted["W_rows"] = W_rows
    flat = pack_fd_partitions_csr(
        wed, res.part, res.support_init, n_parts, bucket=True, flat=True)
    return slotted, flat


def _tip_pack():
    from repro.core import csr
    from repro.core.distributed import pack_fd_partitions_tip_csr
    from repro.core.graph import random_bipartite
    from repro.core.peel import tip_decomposition

    g = random_bipartite(30, 24, 140, seed=0)
    wed = csr.build_wedges(g)
    res = tip_decomposition(g, side="u", P=4, engine="csr")
    n_parts = int(res.part.max()) + 1
    stacked = pack_fd_partitions_tip_csr(
        wed, wed.pair_butterflies0(), res.part, res.support_init,
        n_parts, bucket=True, stacked=True)
    bucketed = pack_fd_partitions_tip_csr(
        wed, wed.pair_butterflies0(), res.part, res.support_init,
        n_parts, bucket=True)
    return stacked, bucketed


def fused_wing_jaxpr() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core.peel import _fd_wing_fused_impl

    p, _ = _wing_pack()
    return str(jax.make_jaxpr(
        lambda *a: _fd_wing_fused_impl(*a, interpret=True))(
        jnp.asarray(p["slot_e1"]), jnp.asarray(p["slot_e2"]),
        jnp.asarray(p["slot_valid"]), jnp.asarray(p["W_rows"]),
        jnp.asarray(p["mine"]), jnp.asarray(p["sup0"]))).strip()


def fused_tip_jaxpr() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core.peel import _fd_tip_fused_impl

    p, _ = _tip_pack()
    return str(jax.make_jaxpr(
        lambda *a: _fd_tip_fused_impl(*a, interpret=True))(
        jnp.asarray(p["st_pa"]), jnp.asarray(p["st_pb"]),
        jnp.asarray(p["st_bf"]), jnp.asarray(p["mine"]),
        jnp.asarray(p["sup0"]))).strip()


def vmapped_wing_jaxpr() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core.peel import _fd_wing_vmapped

    _, p = _wing_pack()
    n_pairs = int(p["flat_W0"].shape[0])
    return str(jax.make_jaxpr(
        lambda *a: _fd_wing_vmapped(*a, n_pairs=n_pairs))(
        jnp.asarray(p["flat_we1"]), jnp.asarray(p["flat_we2"]),
        jnp.asarray(p["flat_wp"]), jnp.asarray(p["flat_alive0"]),
        jnp.asarray(p["flat_W0"]), jnp.asarray(p["mine"]),
        jnp.asarray(p["sup0"]))).strip()


def vmapped_tip_jaxpr() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core.peel import _fd_tip_vmapped

    _, p = _tip_pack()
    return str(jax.make_jaxpr(_fd_tip_vmapped)(
        jnp.asarray(p["pa"]), jnp.asarray(p["pb"]),
        jnp.asarray(p["bf"]), jnp.asarray(p["mine"]),
        jnp.asarray(p["sup0"]))).strip()


def device_wing_jaxpr() -> str:
    """Per-partition wing FD while_loop on a fixed synthetic shape —
    the program streaming's localized re-runs (``run_fd(only=...)``)
    dispatch per dirty partition.  A jaxpr is a function of shapes and
    statics only, so no graph artifacts are needed."""
    import jax
    import jax.numpy as jnp

    from repro.core.peel import _fd_wing_device

    m, n_pairs, n_kept = 140, 64, 96
    mine = jnp.zeros((m,), bool)
    sup0 = jnp.zeros((m,), jnp.int32)
    alive = jnp.zeros((n_kept,), bool)
    W0 = jnp.zeros((n_pairs,), jnp.int32)
    we = jnp.zeros((n_kept,), jnp.int32)
    return str(jax.make_jaxpr(
        lambda *a: _fd_wing_device(*a, n_pairs=n_pairs, m=m))(
        mine, sup0, alive, W0, we, we, we)).strip()


def device_tip_jaxpr() -> str:
    """Per-partition tip FD while_loop on a fixed synthetic shape (the
    tip twin of :func:`device_wing_jaxpr`)."""
    import jax
    import jax.numpy as jnp

    from repro.core.peel import _fd_tip_device

    n, n_pairs = 30, 40
    mine = jnp.zeros((n,), bool)
    sup0 = jnp.zeros((n,), jnp.int32)
    pa = jnp.zeros((n_pairs,), jnp.int32)
    return str(jax.make_jaxpr(
        lambda *a: _fd_tip_device(*a, n=n))(
        mine, sup0, pa, pa, pa)).strip()


def multiserve_dispatch_jaxpr() -> str:
    """Dispatch jaxpr on a fixed synthetic bucket shape (the program is
    a function of shapes only, so no artifacts are needed)."""
    import jax
    import jax.numpy as jnp

    from repro.hierarchy import multiserve

    cap, n_pad, e_pad, J, batch = 4, 16, 16, 4, 64
    z2e = jnp.zeros((cap, e_pad), jnp.int32)
    z2n = jnp.zeros((cap, n_pad), jnp.int32)
    up = jnp.zeros((cap, n_pad, J), jnp.int32)
    z = jnp.zeros(batch, jnp.int32)
    return str(jax.make_jaxpr(
        lambda *x: multiserve._answer_batch_multi(*x, J=J))(
        z2e, z2e, z2n, z2n, z2n, up, z, z, z, z)).strip()


def cd_pair_aligned_jaxpr() -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(CD_PAIR_ALIGNED_SRC)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-4000:])
    return out.stdout.strip()


CASES = {
    "fused_wing": fused_wing_jaxpr,
    "fused_tip": fused_tip_jaxpr,
    "vmapped_wing": vmapped_wing_jaxpr,
    "vmapped_tip": vmapped_tip_jaxpr,
    "device_wing": device_wing_jaxpr,
    "device_tip": device_tip_jaxpr,
    "multiserve_dispatch": multiserve_dispatch_jaxpr,
    "cd_pair_aligned_8dev": cd_pair_aligned_jaxpr,
}


def main() -> None:
    import jax

    golden = {"jax": jax.__version__, "jaxprs": {}}
    for name, fn in CASES.items():
        txt = fn()
        golden["jaxprs"][name] = txt
        print(f"[record-obs] {name}: {len(txt)} chars")
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1)
    print(f"[record-obs] wrote {len(golden['jaxprs'])} jaxprs -> "
          f"{GOLDEN_PATH}")


if __name__ == "__main__":
    main()
