"""Record fixed-seed peeling goldens — the pre-refactor oracle.

Run from the repo root at the commit whose behaviour is the contract::

    PYTHONPATH=src python tests/goldens/record_peel_goldens.py

The unified entity-agnostic core (``core.peelspec``) must reproduce
these θ vectors AND the CD/FD provenance (partition assignment, range
boundaries, per-round and per-update counts) bit-for-bit; the
comparison lives in ``tests/test_peelspec_goldens.py``.  Regenerating
this file is only legitimate when peeling SEMANTICS intentionally
change — a refactor never needs to.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.graph import powerlaw_bipartite, random_bipartite
from repro.core.peel import tip_decomposition, wing_decomposition

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "peel_goldens.json")

GRAPHS = [
    ("rb30", lambda: random_bipartite(30, 24, 140, seed=0)),
    ("rb25", lambda: random_bipartite(25, 20, 100, seed=1)),
    ("pl80", lambda: powerlaw_bipartite(80, 40, 350, seed=2)),
    ("pl60", lambda: powerlaw_bipartite(60, 50, 300, seed=3)),
]


def _record(res) -> dict:
    s = res.stats
    return dict(
        theta=np.asarray(res.theta).tolist(),
        part=np.asarray(res.part).tolist(),
        ranges=np.asarray(res.ranges).tolist(),
        support_init=np.asarray(res.support_init).tolist(),
        rho_cd=s.rho_cd,
        rho_fd_total=s.rho_fd_total,
        rho_fd_max=s.rho_fd_max,
        updates=s.updates,
        recounts=s.recounts,
        p_effective=s.p_effective,
    )


def main() -> None:
    goldens = {}
    for gname, make in GRAPHS:
        g = make()
        for P in (3, 6):
            for engine in ("beindex", "dense", "csr"):
                drivers = (("device", "host", "vmapped")
                           if engine == "csr" else ("device",))
                for fd in drivers:
                    key = f"wing.{gname}.P{P}.{engine}.{fd}"
                    res = wing_decomposition(
                        g, P=P, engine=engine, fd_driver=fd)
                    goldens[key] = _record(res)
            for side in ("u", "v"):
                for engine in ("dense", "csr"):
                    drivers = (("device", "host", "vmapped")
                               if engine == "csr" else ("device",))
                    for fd in drivers:
                        key = f"tip.{gname}.P{P}.{side}.{engine}.{fd}"
                        res = tip_decomposition(
                            g, side=side, P=P, engine=engine, fd_driver=fd)
                        goldens[key] = _record(res)
        print(f"[goldens] {gname}: done")
    with open(OUT, "w") as f:
        json.dump(goldens, f, sort_keys=True)
    print(f"[goldens] wrote {len(goldens)} cases -> {OUT}")


if __name__ == "__main__":
    main()
