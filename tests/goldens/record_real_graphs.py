"""Record real-dataset θ checksums — the end-to-end ingest→peel oracle.

Run from the repo root at the commit whose behaviour is the contract::

    PYTHONPATH=src python tests/goldens/record_real_graphs.py

Each entry pins one real edge-list dataset all the way through the
out-of-core path: chunked ingest (``data.ingest``), bounded-tile ⋈init
(``core.csr.tiled_butterfly_init``) and a full peel, recorded as the
sha256 of the int64 θ vector plus the graph invariants the ingest must
reproduce.  ``tests/test_ingest.py`` replays the pipeline and compares;
the nightly real-graph CI job asserts the same checksums on the
downloaded KONECT originals.  Regenerating is only legitimate when
peeling or ingestion SEMANTICS intentionally change.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import numpy as np

from repro.core import csr
from repro.core.peel import tip_decomposition, wing_decomposition
from repro.data import ingest_edges

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "real_graphs.json")
DATASETS = [
    ("southern_women",
     os.path.join(HERE, "..", "..", "datasets", "southern_women.tsv")),
]


def _sha(theta) -> str:
    return hashlib.sha256(
        np.asarray(theta, dtype=np.int64).tobytes()).hexdigest()


def main() -> None:
    goldens = {}
    for name, path in DATASETS:
        with tempfile.TemporaryDirectory() as td:
            ig = ingest_edges(path, out_dir=os.path.join(td, "ing"))
            g = ig.as_graph()
            sup_e, sup_u, total, _ = csr.tiled_butterfly_init(ig)
            wing = wing_decomposition(g, engine="csr", sup0=sup_e)
            tip = tip_decomposition(g, side="u", engine="csr", sup0=sup_u)
            goldens[name] = dict(
                n_u=ig.n_u, n_v=ig.n_v, m=ig.m,
                total_butterflies=int(total),
                theta_wing_sha256=_sha(wing.theta),
                theta_tip_u_sha256=_sha(tip.theta),
            )
            print(name, goldens[name])
    with open(OUT, "w") as f:
        json.dump(goldens, f, indent=1, sort_keys=True)
        f.write("\n")
    print("wrote", OUT)


if __name__ == "__main__":
    main()
