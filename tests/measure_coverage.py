#!/usr/bin/env python
"""Dependency-free line-coverage measurer for the gated packages.

CI gates ``repro.core`` + ``repro.hierarchy`` line coverage with
pytest-cov (``--cov-fail-under``, see .github/workflows/ci.yml); this
script is how the committed floor was *measured* in environments
without pytest-cov: a ``sys.settrace`` line tracer scoped to the two
packages, run under the tier-1 suite, with the executable-line
denominator taken from the compiled code objects (``co_lines``) — the
same statement universe coverage.py counts, minus its arc analysis, so
the number tracks pytest-cov's within a couple of points.  The CI
floor is set BELOW the measured value by a safety margin; it exists to
catch wholesale coverage collapse (a skipped test file, an
accidentally-disabled parametrize), not single-line drift.

    PYTHONPATH=src python tests/measure_coverage.py [pytest args...]
"""
from __future__ import annotations

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PACKAGES = ("src/repro/core", "src/repro/hierarchy")


def _executable_lines(path: str) -> set:
    """Line numbers of compiled statements (recursing into nested code
    objects) — coverage.py's statement universe."""
    with open(path) as f:
        src = f.read()
    lines: set = set()

    def walk(code):
        for _, _, ln in code.co_lines():
            if ln is not None:
                lines.add(ln)
        for const in code.co_consts:
            if hasattr(const, "co_lines"):
                walk(const)

    walk(compile(src, path, "exec"))
    return lines


def main() -> int:
    targets = {}
    for pkg in PACKAGES:
        base = os.path.join(ROOT, pkg)
        for dirpath, _, files in os.walk(base):
            for fn in files:
                if fn.endswith(".py"):
                    p = os.path.abspath(os.path.join(dirpath, fn))
                    targets[p] = _executable_lines(p)

    hits = {p: set() for p in targets}

    def tracer(frame, event, arg):
        fn = frame.f_code.co_filename
        if fn not in hits:
            return None
        if event == "line":
            hits[fn].add(frame.f_lineno)
        return tracer

    import pytest

    sys.settrace(tracer)
    try:
        rc = pytest.main(["-q", "-p", "no:cacheprovider"]
                         + sys.argv[1:])
    finally:
        sys.settrace(None)

    total_exec = total_hit = 0
    by_pkg = {pkg: [0, 0] for pkg in PACKAGES}
    for p, exe in sorted(targets.items()):
        h = len(hits[p] & exe)
        total_exec += len(exe)
        total_hit += h
        for pkg in PACKAGES:
            if os.path.join(ROOT, pkg) in p:
                by_pkg[pkg][0] += h
                by_pkg[pkg][1] += len(exe)
        pct = 100.0 * h / len(exe) if exe else 100.0
        print(f"{os.path.relpath(p, ROOT):60s} {h:5d}/{len(exe):5d} "
              f"{pct:5.1f}%")
    for pkg, (h, e) in by_pkg.items():
        print(f"[coverage] {pkg}: {100.0 * h / max(e, 1):.1f}% "
              f"({h}/{e} lines)")
    print(f"[coverage] TOTAL (gated packages): "
          f"{100.0 * total_hit / max(total_exec, 1):.1f}% "
          f"({total_hit}/{total_exec} lines)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
