"""The entity-agnostic peeling core (``core.peelspec``) must reproduce
the pre-refactor engines bit-for-bit: θ AND the CD/FD provenance
(partition assignment, range boundaries, ⋈init snapshot, round/update/
recount counts) against fixed-seed goldens recorded at the commit
BEFORE the tip/wing fork was collapsed (``tests/goldens/
peel_goldens.json``; regeneration recipe in ``record_peel_goldens.py``).

A golden mismatch means the refactor changed peeling SEMANTICS, not
just structure — never regenerate to make it pass.
"""
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ref
from repro.core.graph import powerlaw_bipartite, random_bipartite
from repro.core.peel import tip_decomposition, wing_decomposition

GOLDENS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "goldens", "peel_goldens.json")

_GRAPHS = {
    "rb30": lambda: random_bipartite(30, 24, 140, seed=0),
    "rb25": lambda: random_bipartite(25, 20, 100, seed=1),
    "pl80": lambda: powerlaw_bipartite(80, 40, 350, seed=2),
    "pl60": lambda: powerlaw_bipartite(60, 50, 300, seed=3),
}

_FIELDS = ("theta", "part", "ranges", "support_init", "rho_cd",
           "rho_fd_total", "rho_fd_max", "updates", "recounts",
           "p_effective")


def _snapshot(res) -> dict:
    s = res.stats
    return dict(
        theta=np.asarray(res.theta).tolist(),
        part=np.asarray(res.part).tolist(),
        ranges=np.asarray(res.ranges).tolist(),
        support_init=np.asarray(res.support_init).tolist(),
        rho_cd=s.rho_cd, rho_fd_total=s.rho_fd_total,
        rho_fd_max=s.rho_fd_max, updates=s.updates,
        recounts=s.recounts, p_effective=s.p_effective,
    )


def _load():
    with open(GOLDENS) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def goldens():
    return _load()


@pytest.mark.parametrize("gname", sorted(_GRAPHS))
def test_wing_matches_pre_refactor_goldens(goldens, gname):
    g = _GRAPHS[gname]()
    cases = [k for k in goldens if k.startswith(f"wing.{gname}.")]
    assert cases, "golden file lost its wing cases"
    for key in cases:
        _, _, Ps, engine, fd = key.split(".")
        res = wing_decomposition(
            g, P=int(Ps[1:]), engine=engine, fd_driver=fd)
        got = _snapshot(res)
        for f in _FIELDS:
            assert got[f] == goldens[key][f], (key, f)


@pytest.mark.parametrize("gname", sorted(_GRAPHS))
def test_tip_matches_pre_refactor_goldens(goldens, gname):
    g = _GRAPHS[gname]()
    cases = [k for k in goldens if k.startswith(f"tip.{gname}.")]
    assert cases, "golden file lost its tip cases"
    for key in cases:
        _, _, Ps, side, engine, fd = key.split(".")
        res = tip_decomposition(
            g, side=side, P=int(Ps[1:]), engine=engine, fd_driver=fd)
        got = _snapshot(res)
        for f in _FIELDS:
            assert got[f] == goldens[key][f], (key, f)


def test_golden_coverage():
    """The golden file spans every engine × fd_driver cell of both
    entity kinds (so a silently skipped cell cannot hide a fork)."""
    goldens = _load()
    wing_cells = {tuple(k.split(".")[3:]) for k in goldens
                  if k.startswith("wing.")}
    tip_cells = {tuple(k.split(".")[4:]) for k in goldens
                 if k.startswith("tip.")}
    assert {("beindex", "device"), ("dense", "device"),
            ("csr", "device"), ("csr", "host"),
            ("csr", "vmapped")} <= wing_cells
    assert {("dense", "device"), ("csr", "device"), ("csr", "host"),
            ("csr", "vmapped")} <= tip_cells


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5))
def test_unified_core_driver_parity_property(seed, P):
    """Property: on random graphs, every csr fd_driver (and the tip
    Pallas CD path) produces identical θ, partitioning AND round/update
    counts — and θ matches the BUP oracle."""
    g = random_bipartite(18, 14, 60, seed=seed)

    base = wing_decomposition(g, P=P, engine="csr")
    assert np.array_equal(base.theta, ref.bup_wing_ref(g))
    for fd in ("host", "vmapped"):
        other = wing_decomposition(g, P=P, engine="csr", fd_driver=fd)
        assert np.array_equal(other.theta, base.theta), fd
        assert np.array_equal(other.part, base.part), fd
        assert other.stats.rho_fd_total == base.stats.rho_fd_total, fd
        assert other.stats.rho_fd_max == base.stats.rho_fd_max, fd
        assert other.stats.updates == base.stats.updates, fd

    tbase = tip_decomposition(g, side="u", P=P, engine="csr")
    assert np.array_equal(tbase.theta, ref.bup_tip_ref(g, "u"))
    for fd in ("host", "vmapped"):
        other = tip_decomposition(g, side="u", P=P, engine="csr",
                                  fd_driver=fd)
        assert np.array_equal(other.theta, tbase.theta), fd
        assert np.array_equal(other.part, tbase.part), fd
        assert other.stats.rho_fd_total == tbase.stats.rho_fd_total, fd
        assert other.stats.rho_fd_max == tbase.stats.rho_fd_max, fd
    tpal = tip_decomposition(g, side="u", P=P, engine="csr",
                             use_pallas=True)
    assert np.array_equal(tpal.theta, tbase.theta)
    assert tpal.stats.updates == tbase.stats.updates


def test_stats_side_tag_round_trips():
    """PeelStats.side distinguishes tip sides in bench/report rows and
    survives the as_dict/from_dict round-trip."""
    from repro.core.peel import PeelStats

    g = random_bipartite(20, 15, 70, seed=3)
    for side in ("u", "v"):
        res = tip_decomposition(g, side=side, P=3, engine="csr")
        assert res.stats.side == side
        rt = PeelStats.from_dict(res.stats.as_dict())
        assert rt.side == side and rt.engine == "csr"
    resw = wing_decomposition(g, P=3, engine="csr")
    assert resw.stats.side == ""
