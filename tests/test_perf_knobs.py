"""§Perf optimization levers must be numerically exact vs baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_config
from repro.models.config import reduced
from repro.models.layers import blockwise_attention


def _loss(cfg, params, batch):
    return float(M.train_loss(params, batch, cfg))


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("tinyllama_1_1b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = dict(
        tokens=jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 64)),
            jnp.int32),
        labels=jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (2, 64)),
            jnp.int32),
    )
    return cfg, params, batch


def test_causal_skip_exact(setup):
    cfg, params, batch = setup
    base = _loss(cfg, params, batch)
    opt = _loss(dataclasses.replace(cfg, attn_causal_skip=True),
                params, batch)
    assert abs(base - opt) < 1e-5


def test_causal_skip_attention_matches_dense_blocks():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 256, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 256, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 256, 32))
    for bq in (32, 64, 128):
        a = blockwise_attention(q, k, v, causal=True, block_q=bq,
                                block_k=bq)
        b = blockwise_attention(q, k, v, causal=True, block_q=bq,
                                block_k=bq, causal_skip=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)
    # unrolled variant (cost-analysis mode) identical too
    c = blockwise_attention(q, k, v, causal=True, block_q=64, block_k=64,
                            causal_skip=True, unroll=True)
    np.testing.assert_allclose(np.asarray(a if False else b),
                               np.asarray(c), atol=1e-5)


def test_chunked_loss_exact(setup):
    cfg, params, batch = setup
    base = _loss(cfg, params, batch)
    for chunk in (8, 16, 32):
        opt = _loss(dataclasses.replace(cfg, loss_chunk=chunk),
                    params, batch)
        assert abs(base - opt) < 1e-4, (chunk, base, opt)


def test_remat_policies_exact(setup):
    cfg, params, batch = setup
    base = _loss(cfg, params, batch)
    for pol in ("dots", "none"):
        opt = _loss(dataclasses.replace(cfg, remat_policy=pol),
                    params, batch)
        assert abs(base - opt) < 1e-5
    # gradients identical as well
    g1 = jax.grad(lambda p: M.train_loss(p, batch, cfg))(params)
    g2 = jax.grad(lambda p: M.train_loss(
        p, batch, dataclasses.replace(cfg, remat_policy="dots")))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)


def test_bloom_aligned_cd_matches(tmp_path):
    """One-psum bloom-aligned CD ≡ baseline CD (subprocess, 8 devices)."""
    import os
    import subprocess
    import sys
    import textwrap
    ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core.distributed import distributed_wing_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = powerlaw_bipartite(80, 40, 350, seed=9)
        t1, _ = distributed_wing_decomposition(g, mesh, P_parts=6)
        t2, _ = distributed_wing_decomposition(
            g, mesh, P_parts=6, bloom_aligned=True)
        assert np.array_equal(t1, t2)
        print("BLOOM_OK")
    """)
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "BLOOM_OK" in out.stdout


def test_mla_absorb_exact():
    """Absorbed MLA decode ≡ naive MLA decode."""
    import repro.models as M_
    cfg = reduced(get_config("deepseek_v2_236b"))
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    params = M_.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    s = 6
    toks = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab, (2, s)), jnp.int32)

    def run(c):
        cache = jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            M_.cache_specs(c, 2, s, dtype=jnp.float32))
        outs = []
        for t in range(s):
            lg, cache = M_.serve_step(params, cache, toks[:, t],
                                      jnp.int32(t), c)
            outs.append(np.asarray(lg))
        return np.stack(outs)

    np.testing.assert_allclose(run(cfg), run(cfg_a), atol=1e-4)
