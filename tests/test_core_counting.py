"""Counting + BE-Index correctness vs pure-python oracles."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import counting, ref
from repro.core.beindex import build_beindex
from repro.core.graph import BipartiteGraph, powerlaw_bipartite, random_bipartite


def graphs(max_u=24, max_v=20, max_m=80):
    return st.builds(
        lambda nu, nv, m, seed: random_bipartite(nu, nv, m, seed=seed),
        st.integers(2, max_u), st.integers(2, max_v),
        st.integers(0, max_m), st.integers(0, 10_000),
    )


@settings(max_examples=60, deadline=None)
@given(graphs())
def test_vertex_counts_match_oracle(g):
    A = jnp.asarray(g.adjacency())
    bu, bv = ref.vertex_butterflies_ref(g)
    got_u = np.rint(np.asarray(counting.vertex_butterflies(A))).astype(np.int64)
    got_v = np.rint(
        np.asarray(counting.vertex_butterflies(A.T))
    ).astype(np.int64)
    assert np.array_equal(got_u, bu)
    assert np.array_equal(got_v, bv)


@settings(max_examples=40, deadline=None)
@given(graphs())
def test_edge_counts_match_oracle(g):
    if g.m == 0:
        return
    A = jnp.asarray(g.adjacency())
    e = jnp.asarray(g.edges.astype(np.int32))
    got = np.rint(np.asarray(counting.edge_butterflies(A, e))).astype(np.int64)
    assert np.array_equal(got, ref.edge_butterflies_ref(g))


@settings(max_examples=25, deadline=None)
@given(graphs(), st.sampled_from([4, 8, 16]))
def test_blocked_counting_matches_full(g, block):
    A = jnp.asarray(g.adjacency())
    full = np.asarray(counting.vertex_butterflies(A))
    blk = np.asarray(counting.vertex_butterflies_blocked(A, block=block))
    np.testing.assert_allclose(full, blk, rtol=0, atol=0.5)


@settings(max_examples=30, deadline=None)
@given(graphs())
def test_beindex_partitions_all_butterflies(g):
    """Property 2: every butterfly is in exactly one maximal priority bloom."""
    be = build_beindex(g)
    assert be.total_butterflies() == ref.butterfly_count_total(g)


@settings(max_examples=30, deadline=None)
@given(graphs())
def test_beindex_edge_support(g):
    """Property 1 corollary: ⋈_e = Σ_{B∋e} (k_B − 1)."""
    be = build_beindex(g)
    assert np.array_equal(be.edge_support(g.m), ref.edge_butterflies_ref(g))


def test_total_butterflies_powerlaw():
    g = powerlaw_bipartite(150, 70, 600, seed=11)
    A = jnp.asarray(g.adjacency())
    got = float(counting.total_butterflies(A))
    assert int(round(got)) == ref.butterfly_count_total(g)


def test_wedge_workload_proxy():
    g = random_bipartite(30, 25, 120, seed=5)
    A = jnp.asarray(g.adjacency())
    wu, _ = ref.wedge_count_ref(g)
    got = np.rint(np.asarray(counting.vertex_wedge_workload(A))).astype(np.int64)
    assert np.array_equal(got, wu)


def test_masked_adjacency_respects_alive():
    g = random_bipartite(10, 10, 30, seed=1)
    alive = jnp.asarray(np.arange(g.m) % 2 == 0)
    A = counting.masked_adjacency(
        (g.n_u, g.n_v), jnp.asarray(g.edges.astype(np.int32)), alive
    )
    assert float(A.sum()) == float(alive.sum())


def test_known_small_graph():
    # fig.1a of the paper: a 1-wing where every edge is in >= 1 butterfly
    # 2x2 biclique has exactly one butterfly
    g = BipartiteGraph.from_edges(2, 2, [[0, 0], [0, 1], [1, 0], [1, 1]])
    assert ref.butterfly_count_total(g) == 1
    assert np.array_equal(ref.edge_butterflies_ref(g), np.ones(4, np.int64))
    # (2,3)-biclique: C(3,2)=3 butterflies, each edge in 2
    g = BipartiteGraph.from_edges(
        2, 3, [[u, v] for u in range(2) for v in range(3)]
    )
    assert ref.butterfly_count_total(g) == 3
    assert np.array_equal(ref.edge_butterflies_ref(g), np.full(6, 2))


def test_vertex_butterflies_autoroutes_oversized(monkeypatch):
    """Past REPRO_DENSE_MAX_ELEMS the dense reduction must route itself
    through the row-blocked path (same values) and emit the obs
    ``counting.tiles`` counter instead of failing."""
    from repro import obs

    g = random_bipartite(40, 30, 200, seed=7)
    A = jnp.asarray(g.adjacency())
    want = np.asarray(counting.vertex_butterflies(A))
    monkeypatch.setenv("REPRO_DENSE_MAX_ELEMS", "64")
    obs.enable()
    try:
        got = np.asarray(counting.vertex_butterflies(A))
        events = [e for e in obs.get_tracer().events
                  if e["name"] == "counting.tiles"]
    finally:
        obs.disable()
    assert np.array_equal(got, want)
    assert events and events[0]["args"]["rows"] == g.n_u
