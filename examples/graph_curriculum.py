"""PBNG → LM bridge: wing-decompose a user×item graph, build a
dense-subgraph curriculum, and train a small LM on link prediction —
the paper's recommendation-system application end to end.

    PYTHONPATH=src python examples/graph_curriculum.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import get_config
from repro.core import powerlaw_bipartite
from repro.data import curriculum_sequences, sequence_batches
from repro.models.config import reduced
from repro.train import TrainConfig, make_train_step
from repro.train.optimizer import adamw_init, AdamWConfig

# 1. interaction graph -> density-ordered training sequences
g = powerlaw_bipartite(n_u=200, n_v=100, m=1200, seed=3)
seqs = curriculum_sequences(g, n_levels=4, P=8, max_len=32)
print(f"curriculum: {len(seqs)} sequences from {g.m} interactions "
      f"(densest first)")

# 2. a small LM whose vocabulary is the node set
cfg = reduced(get_config("tinyllama_1_1b"),
              vocab=g.n_u + g.n_v, max_seq=32, n_layers=2)
params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
opt = adamw_init(params)
step = jax.jit(make_train_step(
    cfg, TrainConfig(opt=AdamWConfig(lr=1e-2, total_steps=200))))

# 3. train on the curriculum (dense cores first)
losses = []
epochs = 3
for epoch in range(epochs):
    for batch in sequence_batches(seqs, batch=16, seq_len=31):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
print(f"link-prediction loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"over {len(losses)} steps")
assert losses[-1] < losses[0], "training diverged"
print("curriculum training ✓")
