"""From θ to hierarchy: build the dense-subgraph DAG once, then answer
batched queries from it — the paper's actual deliverable as a service.

    PYTHONPATH=src python examples/hierarchy_queries.py
"""
import numpy as np

from repro.core import powerlaw_bipartite, wing_decomposition
from repro.hierarchy import (
    HierarchyService,
    HQuery,
    build_hierarchy,
    density_profile,
    lca_entities,
    load_hierarchy,
    pack_forest,
    save_hierarchy,
    subgraph_at,
    top_densest_leaves,
)

# A user×item interaction graph with realistic degree skew.
g = powerlaw_bipartite(n_u=300, n_v=120, m=1500, seed=42)
res = wing_decomposition(g, P=16, engine="csr")

# --- decompose once ...
h = build_hierarchy(g, res, kind="wing")
print(f"hierarchy: {h.n_nodes} nodes over {h.levels.size} levels "
      f"(engine={h.meta['stats']['engine']})")

# ... serialize (versioned npz: compute once, serve forever) ...
save_hierarchy("/tmp/hierarchy_wing.npz", h)
h = load_hierarchy("/tmp/hierarchy_wing.npz")

# --- one-shot analytics on the forest
prof = density_profile(h, int(h.levels[0]))
print(f"k={prof['k']}: {prof['n_components']} dense components, "
      f"sizes {sorted(prof['sizes'].tolist(), reverse=True)[:5]} ...")
top = top_densest_leaves(h, 3)
print(f"densest leaves: density={np.round(top['density'], 3).tolist()} "
      f"at k={top['level'].tolist()}")

# --- point queries on the device-resident packed forest
f = pack_forest(h)
e1, e2 = 3, 17
lca = int(np.asarray(lca_entities(f, [e1], [e2]))[0])
print(f"smallest dense subgraph containing edges {e1} and {e2}: "
      f"node {lca} at k={int(h.node_level[lca])} "
      f"with {int(h.eend[lca] - h.estart[lca])} edges")
mask = np.asarray(subgraph_at(f, [lca]))[0]
print(f"  its edge mask selects {int(mask.sum())} of {g.m} edges")

# --- batched mixed-op serving (the production path)
svc = HierarchyService(h, batch=256)
rng = np.random.default_rng(0)
for i in range(1000):
    op = ["max_k", "node_of", "lca_level"][i % 3]
    svc.submit(HQuery(uid=i, op=op,
                      a=int(rng.integers(0, g.m)),
                      b=int(rng.integers(0, g.m))))
done = svc.run()
print(f"served {svc.served} mixed queries in {svc.dispatches} "
      f"batched dispatches; sample answers "
      f"{[q.result for q in done[:6]]}")
