"""MoE routing analysis with PBNG: tip-decompose the token×expert graph
of a (reduced) DBRX MoE layer to find densely co-activated expert
groups — offline diagnostics for expert placement.

    PYTHONPATH=src python examples/moe_affinity.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import get_config
from repro.core.analysis import moe_affinity, routing_graph
from repro.models.config import reduced

cfg = reduced(get_config("dbrx_132b"))
params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

# route a batch of tokens through layer-0's router
x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
router = params["blocks"]["ffn"]["router"][0]
logits = jnp.einsum("bsd,de->bse", x, router).reshape(-1, cfg.n_experts)
_, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
assignments = np.asarray(idx)
print(f"routed {assignments.shape[0]} tokens to top-{cfg.top_k} of "
      f"{cfg.n_experts} experts")

g = routing_graph(assignments, cfg.n_experts)
tips = moe_affinity(assignments, cfg.n_experts, P=4)
order = np.argsort(-tips)
print("expert co-activation tip numbers (densest first):")
for e in order:
    print(f"  expert {e:2d}: tip={tips[e]:6d}")
print("experts in the same high-tip core are EP-shard co-location "
      "candidates")
