"""Quickstart: decompose a bipartite network with PBNG in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    powerlaw_bipartite,
    tip_decomposition,
    wing_decomposition,
    ref,
)

# A user×item interaction graph with realistic degree skew.
g = powerlaw_bipartite(n_u=300, n_v=120, m=1500, seed=42)
print(f"graph: |U|={g.n_u} |V|={g.n_v} |E|={g.m} "
      f"butterflies={ref.butterfly_count_total(g)}")

# --- wing decomposition (edge peeling): the dense-subgraph hierarchy
res = wing_decomposition(g, P=32, engine="beindex")
theta = res.theta
print(f"wing numbers: max={theta.max()} "
      f"levels={np.unique(theta).size}")
print(f"synchronization: {res.stats.rho_cd} global rounds (CD; FD is "
      f"sync-free) vs {res.stats.rho_fd_total} level-by-level rounds "
      f"-> {res.stats.sync_reduction:.1f}x reduction; "
      f"FD critical path {res.stats.rho_fd_max} rounds on "
      f"{res.stats.p_effective} independent partitions")

# densest community core = edges at the top wing-number level
top = g.edges[theta >= np.quantile(theta, 0.95)]
print(f"densest 5% core: {top.shape[0]} edges touching "
      f"{np.unique(top[:, 0]).size} users / "
      f"{np.unique(top[:, 1]).size} items")

# --- tip decomposition (vertex peeling): per-user density
res_u = tip_decomposition(g, side="u", P=8)
print(f"tip numbers (users): max={res_u.theta.max()}")

# cross-check against the sequential oracle on a subsample
g_small = powerlaw_bipartite(60, 30, 220, seed=7)
assert np.array_equal(
    wing_decomposition(g_small, P=4).theta, ref.bup_wing_ref(g_small))
print("PBNG ≡ bottom-up peeling: verified ✓")
