"""Continuous-batching serving: a stream of requests with mixed lengths
shares a slot pool — late arrivals join as early finishers retire.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import get_config
from repro.models.config import reduced
from repro.serve import ContinuousBatcher, Request

cfg = reduced(get_config("tinyllama_1_1b"), n_layers=2)
params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

rng = np.random.default_rng(0)
eng = ContinuousBatcher(cfg, params, n_slots=4, max_seq=96)
n_req = 10
for i in range(n_req):
    eng.submit(Request(
        uid=i,
        prompt=rng.integers(0, cfg.vocab, size=int(rng.integers(3, 9))).tolist(),
        max_new=int(rng.integers(4, 12)),
    ))

t0 = time.time()
done = eng.run()
dt = time.time() - t0
tok = sum(len(r.output) for r in done)
print(f"[continuous] {len(done)}/{n_req} requests, {tok} tokens in "
      f"{dt:.2f}s over {eng.steps} engine steps "
      f"({eng.steps / max(len(done),1):.1f} steps/req vs "
      f"{sum(len(r.prompt)+len(r.output) for r in done)/len(done):.1f} "
      f"serial)")
for r in done[:3]:
    print(f"  req {r.uid}: prompt {len(r.prompt)} -> {r.output}")
