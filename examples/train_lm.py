"""End-to-end training driver example: a reduced TinyLlama on synthetic
data for a few hundred steps, with checkpointing and auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys
import types

from repro.launch.train import run

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="tinyllama_1_1b")
ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
a = ap.parse_args()

args = types.SimpleNamespace(
    arch=a.arch, reduced=True, steps=a.steps, batch=8, seq=128,
    lr=3e-3, microbatches=1, seed=0, log_every=20,
    ckpt_dir=a.ckpt_dir, ckpt_every=100, resume="auto", crash_at=None,
)
sys.exit(run(args))
